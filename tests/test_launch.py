"""Launch/roofline machinery tests (no 512-device requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base
from repro.launch import specs as SP
from repro.roofline import analysis as RA


@pytest.fixture(scope="module", autouse=True)
def _load():
    base.load_all()


def test_shapes_cover_40_cells():
    assert len(SP.SHAPES) == 4
    assert len(base.names()) == 10


def test_long500k_gating():
    assert SP.cell_is_runnable(base.get("xlstm-1.3b"), "long_500k")
    assert SP.cell_is_runnable(base.get("zamba2-1.2b"), "long_500k")
    assert SP.cell_is_runnable(base.get("h2o-danube-3-4b"), "long_500k")
    assert not SP.cell_is_runnable(base.get("yi-9b"), "long_500k")
    assert not SP.cell_is_runnable(base.get("arctic-480b"), "long_500k")


@pytest.mark.parametrize("arch", ["yi-9b", "arctic-480b", "seamless-m4t-large-v2"])
@pytest.mark.parametrize("shape", ["train_4k", "prefill_32k", "decode_32k"])
def test_input_specs_wellformed(arch, shape):
    cfg = base.get(arch)
    specs = SP.input_specs(cfg, shape)
    for leaf in jax.tree.leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    meta = SP.SHAPES[shape]
    if meta["kind"] == "train":
        assert specs["batch"]["tokens"].shape == (meta["batch"], meta["seq"])
    elif meta["kind"] == "decode":
        assert specs["token"].shape == (meta["batch"],)
        assert "frontend" not in specs  # cross-KV lives in the cache


def test_param_counts_match_public_sizes():
    """Sanity: parameter totals are in the right ballpark for the names."""
    expected = {
        "yi-9b": (8e9, 10e9),
        "command-r-35b": (28e9, 40e9),  # tied emb counted once
        "nemotron-4-15b": (14e9, 17e9),
        "arctic-480b": (430e9, 520e9),
        "deepseek-v2-lite-16b": (14e9, 18e9),
        "xlstm-1.3b": (1.0e9, 2.2e9),
        "zamba2-1.2b": (0.9e9, 1.6e9),
        "h2o-danube-3-4b": (3.4e9, 4.6e9),
        "llama-3.2-vision-11b": (8e9, 12e9),
        "seamless-m4t-large-v2": (1.4e9, 2.8e9),
    }
    for name, (lo, hi) in expected.items():
        n = RA.param_counts(base.get(name))["total"]
        assert lo <= n <= hi, (name, n)


def test_moe_active_params_below_total():
    c = RA.param_counts(base.get("arctic-480b"))
    assert c["active"] < 0.1 * c["total"]  # top-2 of 128 experts
    c = RA.param_counts(base.get("deepseek-v2-lite-16b"))
    assert c["active"] < 0.5 * c["total"]


def test_parse_collectives_scoped():
    from repro.launch.dryrun import parse_collectives
    hlo = """
ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %ag = f32[64,8]{1,0} all-gather(%p0), replica_groups={}
  ROOT %r = f32[8,8] add(%p0, %p0)
}
%while_body_1 (p: f32[4]) -> f32[4] {
  %ar = bf16[2048,512]{1,0} all-reduce(%x), to_apply=%sum
}
"""
    out = parse_collectives(hlo)
    assert out["bytes"]["all-gather"]["top"] == 64 * 8 * 4
    assert out["bytes"]["all-reduce"]["loop"] == 2048 * 512 * 2


def test_analytic_flops_positive_all_cells():
    for arch in base.names():
        cfg = base.get(arch)
        for shape in SP.SHAPES:
            if not SP.cell_is_runnable(cfg, shape):
                continue
            fl = RA.hlo_flops(cfg, shape)
            assert fl["total"] > 0 and fl["model"] > 0, (arch, shape)
            by = RA.hlo_bytes(cfg, shape)
            assert by > 0


def test_int8_variants_reduce_bytes():
    import dataclasses
    cfg = base.get("yi-9b")
    b0 = RA.hlo_bytes(cfg, "decode_32k")
    b1 = RA.hlo_bytes(dataclasses.replace(cfg, kv_cache_dtype="int8"),
                      "decode_32k")
    b2 = RA.hlo_bytes(dataclasses.replace(cfg, kv_cache_dtype="int8",
                                          serve_weight_dtype="int8"),
                      "decode_32k")
    assert b1 < 0.65 * b0       # cache dominates
    assert b2 < b1
