"""End-to-end system tests: the training driver (with checkpoint/restart and
gradient compression), the serving driver, and the ODiMO search engine's
monotone cost behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import serve, train


@pytest.mark.slow
def test_train_loss_decreases_and_resumes(tmp_path):
    ck = str(tmp_path / "ckpt")
    losses = train.main(["--arch", "yi-9b", "--reduce", "--steps", "30",
                         "--batch", "4", "--seq", "32", "--ckpt-dir", ck,
                         "--ckpt-every", "10", "--log-every", "100"])
    assert losses[-1] < losses[0]
    # resume from the committed checkpoint and run further
    losses2 = train.main(["--arch", "yi-9b", "--reduce", "--steps", "40",
                          "--batch", "4", "--seq", "32", "--ckpt-dir", ck,
                          "--resume", "--log-every", "100"])
    assert len(losses2) == 10  # steps 30..40 only


@pytest.mark.slow
def test_train_with_gradient_compression():
    losses = train.main(["--arch", "h2o-danube-3-4b", "--reduce", "--steps",
                         "25", "--batch", "4", "--seq", "32",
                         "--compress-grads", "--log-every", "100"])
    assert losses[-1] < losses[0]


def test_serve_driver_generates():
    gen, stats = serve.main(["--arch", "deepseek-v2-lite-16b", "--reduce",
                             "--requests", "2", "--prompt-len", "8",
                             "--gen-len", "4"])
    assert gen.shape == (2, 4)
    assert stats["tok_per_s"] > 0


@pytest.mark.slow
def test_odimo_lambda_monotone_cost():
    """Core paper behavior: larger lambda -> cheaper discovered mapping."""
    from repro.api import SearchConfig, SearchPipeline, cnn_handle
    from repro.data.pipeline import ImageTaskConfig, image_batch
    from repro.models import cnn

    cfg = cnn.RESNET20_TINY
    task = ImageTaskConfig(n_classes=cfg.n_classes, img_hw=cfg.img_hw)
    data_fn = lambda step, batch: image_batch(task, step, batch)
    handle = cnn_handle(cfg)
    costs = []
    for lam in (1e-9, 1e-4):
        scfg = SearchConfig(lam=lam, objective="energy",
                            pretrain_steps=20, search_steps=50,
                            finetune_steps=10, batch=16,
                            eval_batches=2)
        res = SearchPipeline(handle, "diana_ideal_shutdown", config=scfg,
                             data_fn=data_fn).run()
        costs.append(res.energy)
    assert costs[1] <= costs[0] * 1.05, costs
