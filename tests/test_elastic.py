"""Elastic rescale end-to-end: save a sharded train state under one mesh,
restore it under a DIFFERENT mesh (fewer devices), continue training, and
verify the loss trajectory matches an uninterrupted run bit-for-bit.

Runs in a subprocess with 8 forced host devices (the test process itself
keeps 1 device; see dryrun.py's device-count note).
"""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint import checkpoint as ckpt
    from repro.configs import base
    from repro.data.pipeline import ShardedLoader, TokenTaskConfig
    from repro.distributed.fault_tolerance import ElasticPlan
    from repro.models import transformer as T
    from repro.optim import adamw

    base.load_all()
    cfg = base.reduce_for_smoke(base.get("yi-9b"))
    ocfg = adamw.AdamWConfig(lr=1e-3)
    data = ShardedLoader("token", TokenTaskConfig(vocab=cfg.vocab),
                         batch=8, seq_len=32)

    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(p, cfg, batch))(params)
        params, opt, _ = adamw.update(grads, opt, params, ocfg)
        return params, opt, loss

    def run(params, opt, mesh, lo, hi):
        dp = NamedSharding(mesh, P("data", None))
        losses = []
        with mesh:
            jstep = jax.jit(step_fn)
            for s in range(lo, hi):
                toks, tgts = data.get(s)
                batch = {"tokens": jax.device_put(toks, dp),
                         "targets": jax.device_put(tgts, dp)}
                params, opt, loss = jstep(params, opt, batch)
                losses.append(float(loss))
        return params, opt, losses

    def put(tree, mesh):
        rep = NamedSharding(mesh, P())
        return jax.tree.map(lambda x: jax.device_put(np.asarray(x), rep), tree)

    params = T.init_lm(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params, ocfg)

    # --- reference: 6 uninterrupted steps on the BIG mesh (8 devices) ---
    mesh8 = jax.make_mesh((8,), ("data",), devices=jax.devices()[:8])
    p_ref, o_ref, losses_ref = run(put(params, mesh8), put(opt, mesh8),
                                   mesh8, 0, 6)

    # --- elastic: 3 steps on 8 devices, checkpoint, RESTORE ON 4, 3 more ---
    plan = ElasticPlan(old_shape=(8, 1), new_hosts=1, chips_per_host=4)
    assert plan.needs_reshard
    p1, o1, losses_a = run(put(params, mesh8), put(opt, mesh8), mesh8, 0, 3)
    ckpt.save("/tmp/elastic_ckpt", 3, (p1, o1), {"step": 3})

    mesh4 = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
    like = (p1, o1)
    rep4 = jax.tree.map(
        lambda x: NamedSharding(mesh4, P()), like)
    p2, o2 = ckpt.restore("/tmp/elastic_ckpt", 3, like, shardings=rep4)
    data.reshard(shard=0, n_shards=1)  # deterministic stream continues
    _, _, losses_b = run(p2, o2, mesh4, 3, 6)

    got = losses_a + losses_b
    np.testing.assert_allclose(got, losses_ref, rtol=2e-4, atol=2e-4)
    print("ELASTIC_OK", got)
""")


@pytest.mark.slow
def test_elastic_rescale_roundtrip(tmp_path):
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         cwd=Path(__file__).resolve().parents[1],
                         capture_output=True, text=True, timeout=1200)
    assert "ELASTIC_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]
