"""End-to-end serving driver (the paper is an INFERENCE-mapping paper, so the
end-to-end example is a serving loop): batched requests against a reduced
LM with prefill + iterative decode over a KV cache.

Run:  PYTHONPATH=src python examples/serve_llm.py [--arch yi-9b]
"""
import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    args = ap.parse_args()
    serve.main(["--arch", args.arch, "--reduce", "--requests", "8",
                "--prompt-len", "32", "--gen-len", "16"])


if __name__ == "__main__":
    main()
