"""End-to-end serving driver (the paper is an INFERENCE-mapping paper, so the
end-to-end example is a serving loop): batched requests against a reduced
LM, served by the `repro.serving` continuous-batching engine.

Run:  PYTHONPATH=src python examples/serve_llm.py [--arch yi-9b] [--engine]

``--engine`` switches from the fixed-shape batch to a mixed-length request
trace with continuous slot admission and per-request TTFT reporting.
"""
import argparse

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--engine", action="store_true",
                    help="mixed-length trace through the continuous-"
                         "batching engine (per-request TTFT/tok-s)")
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--reduce", "--requests", "8",
            "--prompt-len", "32", "--gen-len", "16"]
    if args.engine:
        argv += ["--engine", "--max-batch", "4"]
    serve.main(argv)


if __name__ == "__main__":
    main()
