"""Quickstart: ODiMO end-to-end on a small CNN via the `repro.api` mapping
API, in ~2 minutes on CPU.

  1. pretrain fp32        -> baseline accuracy
  2. DNAS search (Eq. 2)  -> per-channel accelerator assignment
  3. discretize           -> serializable mapping artifact (JSON)
  4. deploy one layer through the fused split-precision Pallas kernel
     (interpret mode on CPU) using the RELOADED artifact, and check it
     matches the fake-quant semantics

Run:  PYTHONPATH=src python examples/quickstart.py [--fast]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.api import (MappingArtifact, SearchConfig, SearchPipeline,
                       VerboseCallback, cnn_handle)
from repro.data.pipeline import ImageTaskConfig, image_batch
from repro.models import cnn


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI-speed step counts (seconds, not minutes)")
    ap.add_argument("--artifact", default="experiments/quickstart_mapping.json")
    args = ap.parse_args(argv)

    cfg = cnn.RESNET20_TINY
    task = ImageTaskConfig(n_classes=cfg.n_classes, img_hw=cfg.img_hw)
    data_fn = lambda step, batch: image_batch(task, step, batch)

    print("=== ODiMO search (latency objective, lambda=5e-7) ===")
    steps = (10, 12, 8) if args.fast else (60, 80, 60)
    scfg = SearchConfig(lam=5e-7, objective="latency",
                        pretrain_steps=steps[0], search_steps=steps[1],
                        finetune_steps=steps[2], batch=32, eval_batches=4)
    pipe = SearchPipeline(cnn_handle(cfg), platform="diana", config=scfg,
                          data_fn=data_fn, callbacks=[VerboseCallback()])
    res = pipe.run()
    print(f"accuracy={res.accuracy:.3f}  modeled latency={res.latency:.3e} "
          f"cycles  energy={res.energy:.3e}")
    print("channel split per layer (digital, aimc):",
          [tuple(int(x) for x in c) for c in res.counts][:8], "...")

    path = res.artifact.save(args.artifact)
    print(f"\n=== mapping artifact -> {path} ===")
    art = MappingArtifact.load(path)   # round-trip through JSON
    print(f"platform={art.platform} layers={len(art.layers)} "
          f"aimc channel fraction={art.domain_channel_fractions()[1]:.1%}")

    print("\n=== fused split-precision kernel deploy (from the artifact) ===")
    # deploy the classifier head through the fused kernel
    head = res.params["head"]
    assign = art.assignments()[-1]
    from repro.core import quant
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(0), (32, head["w"].shape[0]))
    wls = quant.init_log_scale(head["w"])
    xls = quant.init_log_scale(x)
    out_kernel = ops.odimo_deployed_dense(x, head["w"].astype(jnp.float32),
                                          assign, wls, xls, interpret=True)
    # oracle
    xq = quant.fake_quant(x, xls, 8)
    w8 = quant.fake_quant(head["w"].astype(jnp.float32), wls, 8)
    lo = xq @ w8
    hi = (x @ head["w"].astype(jnp.float32))
    expect = jnp.where(jnp.asarray(assign)[None, :] == 0, lo, hi)
    err = float(jnp.max(jnp.abs(out_kernel - expect)))
    print(f"fused-kernel max |err| vs fake-quant oracle: {err:.4f}")
    assert err < 0.3
    print("quickstart OK")


if __name__ == "__main__":
    main()
