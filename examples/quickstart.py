"""Quickstart: ODiMO end-to-end on a small CNN, in ~2 minutes on CPU.

  1. pretrain fp32        -> baseline accuracy
  2. DNAS search (Eq. 2)  -> per-channel accelerator assignment
  3. discretize + Fig. 3 reorg pass  -> contiguous per-domain sub-layers
  4. deploy one layer through the fused split-precision Pallas kernel
     (interpret mode on CPU) and check it matches the fake-quant semantics

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.cost_models import DianaCostModel
from repro.core.odimo import ODiMOSpec
from repro.data.pipeline import ImageTaskConfig, image_batch
from repro.models import cnn


def main():
    cfg = cnn.RESNET20_TINY
    task = ImageTaskConfig(n_classes=cfg.n_classes, img_hw=cfg.img_hw)
    data_fn = lambda step, batch: image_batch(task, step, batch)
    spec = ODiMOSpec()
    cost_model = DianaCostModel()

    print("=== ODiMO search (latency objective, lambda=5e-7) ===")
    scfg = engine.SearchConfig(lam=5e-7, objective="latency",
                               pretrain_steps=60, search_steps=80,
                               finetune_steps=60, batch=32, eval_batches=4)
    res = engine.run_odimo(cnn.get_model(cfg), cfg, spec, cost_model, scfg,
                           data_fn, verbose=True)
    print(f"accuracy={res.accuracy:.3f}  modeled latency={res.latency:.3e} "
          f"cycles  energy={res.energy:.3e}")
    print("channel split per layer (digital, aimc):",
          [tuple(int(x) for x in c) for c in res.counts][:8], "...")

    print("\n=== Fig. 3 reorg + fused split-precision kernel deploy ===")
    # deploy the classifier head through the fused kernel
    head = res.params["head"]
    assign = res.assignments[-1]
    from repro.core import quant
    from repro.kernels import ops
    x = jax.random.normal(jax.random.PRNGKey(0), (32, head["w"].shape[0]))
    wls = quant.init_log_scale(head["w"])
    xls = quant.init_log_scale(x)
    out_kernel = ops.odimo_deployed_dense(x, head["w"].astype(jnp.float32),
                                          assign, wls, xls, interpret=True)
    # oracle
    xq = quant.fake_quant(x, xls, 8)
    w8 = quant.fake_quant(head["w"].astype(jnp.float32), wls, 8)
    lo = xq @ w8
    hi = (x @ head["w"].astype(jnp.float32))
    expect = jnp.where(jnp.asarray(assign)[None, :] == 0, lo, hi)
    err = float(jnp.max(jnp.abs(out_kernel - expect)))
    print(f"fused-kernel max |err| vs fake-quant oracle: {err:.4f}")
    assert err < 0.3
    print("quickstart OK")


if __name__ == "__main__":
    main()
