"""ODiMO with the TPU cost model: per-channel int8/bf16 precision-domain
assignment on an MLP stack (the DESIGN.md §2 adaptation, exercised
end-to-end with the paper's own DNAS machinery via `repro.api`).

The "accelerators" here are the two MXU precision domains of one TPU chip:
  domain 0: int8 path (2x peak FLOP/s, 1-byte weight stream)
  domain 1: bf16 path
The ``"tpu_v5e"`` platform's latency is roofline-based, so channels drift to
the int8 domain until the accuracy regularizer pushes sensitive channels
back — exactly the paper's accuracy-vs-cost trade, on TPU terms.

Run:  PYTHONPATH=src python examples/odimo_tpu_domains.py
"""
from repro.api import SearchConfig, SearchPipeline, mlp_handle
from repro.data.pipeline import ImageTaskConfig, image_batch

IMG_HW = (8, 8)
N_CLASSES = 10


def main():
    handle = mlp_handle(in_dim=IMG_HW[0] * IMG_HW[1] * 3,
                        widths=(128, 256, 256, 128), n_classes=N_CLASSES,
                        name="mlp_tpu_domains")
    task = ImageTaskConfig(n_classes=N_CLASSES, img_hw=IMG_HW, noise=0.6)
    data_fn = lambda step, batch: image_batch(task, step, batch)

    print("=== ODiMO x TPU precision domains (int8 @2x peak vs bf16) ===")
    for lam in (1e2, 1e5):
        scfg = SearchConfig(lam=lam, objective="latency",
                            pretrain_steps=80, search_steps=120,
                            finetune_steps=60, batch=64, eval_batches=4)
        res = SearchPipeline(handle, platform="tpu_v5e", config=scfg,
                             data_fn=data_fn).run()
        int8_frac = float(res.artifact.domain_channel_fractions()[0])
        print(f"lambda={lam:.0e}: acc={res.accuracy:.3f} "
              f"roofline-lat={res.latency:.3e}s int8-channels={int8_frac:.0%}")
    print("higher lambda -> more channels on the fast int8 domain, the")
    print("TPU version of the paper's digital/AIMC trade (DESIGN.md §2)")


if __name__ == "__main__":
    main()
