"""ODiMO with the TPU cost model: per-channel int8/bf16 precision-domain
assignment on a transformer-style FFN stack (the DESIGN.md §2 adaptation,
exercised end-to-end with the paper's own DNAS machinery).

The "accelerators" here are the two MXU precision domains of one TPU chip:
  domain 0: int8 path (2x peak FLOP/s, 1-byte weight stream)
  domain 1: bf16 path
TPUCostModel's latency is roofline-based, so channels drift to the int8
domain until the accuracy regularizer pushes sensitive channels back —
exactly the paper's accuracy-vs-cost trade, on TPU terms.

Run:  PYTHONPATH=src python examples/odimo_tpu_domains.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.cost_models import LayerGeometry, TPUCostModel
from repro.core.odimo import ODiMOSpec
from repro.core.quant import TPU_DOMAINS
from repro.data.pipeline import ImageTaskConfig, image_batch
from repro.models import managed as mg


# ---- a small MLP façade over managed Dense layers (engine-compatible) ----

WIDTHS = [128, 256, 256, 128]
N_CLASSES = 10
IN_DIM = 8 * 8 * 3


class MLPCfg:
    name = "mlp_tpu_domains"


def init_fn(key, cfg, spec):
    ks = jax.random.split(key, len(WIDTHS) + 1)
    dims = [IN_DIM] + WIDTHS
    layers = [mg.init_dense(ks[i], dims[i], dims[i + 1], spec)
              for i in range(len(WIDTHS))]
    head = mg.init_dense(ks[-1], WIDTHS[-1], N_CLASSES, spec)
    return {"layers": layers, "head": head}


def apply_fn(p, x, cfg, spec=None, mode="fp", tau=1.0):
    h = x.reshape(x.shape[0], -1)
    for lp in p["layers"]:
        h = jax.nn.relu(mg.dense(lp, h, spec, mode, tau))
    return mg.dense(p["head"], h, spec, mode, tau)


def plan_fn(cfg):
    dims = [IN_DIM] + WIDTHS
    plan = [(f"layers/{i}", LayerGeometry(c_in=dims[i], c_out=dims[i + 1]),
             True) for i in range(len(WIDTHS))]
    plan.append(("head", LayerGeometry(c_in=WIDTHS[-1], c_out=N_CLASSES),
                 True))
    return plan


def managed_fn(params):
    return list(params["layers"]) + [params["head"]]


def main():
    spec = ODiMOSpec(domains=TPU_DOMAINS, act_bits=8)
    cm = TPUCostModel()
    task = ImageTaskConfig(n_classes=N_CLASSES, img_hw=(8, 8), noise=0.6)
    data_fn = lambda step, batch: image_batch(task, step, batch)

    print("=== ODiMO x TPU precision domains (int8 @2x peak vs bf16) ===")
    for lam in (1e2, 1e5):
        scfg = engine.SearchConfig(lam=lam, objective="latency",
                                   pretrain_steps=80, search_steps=120,
                                   finetune_steps=60, batch=64,
                                   eval_batches=4)
        res = engine.run_odimo((init_fn, apply_fn, plan_fn), MLPCfg(), spec,
                               cm, scfg, data_fn, managed_fn=managed_fn)
        int8_frac = sum(int((a == 0).sum()) for a in res.assignments) / \
            sum(a.size for a in res.assignments)
        print(f"lambda={lam:.0e}: acc={res.accuracy:.3f} "
              f"roofline-lat={res.latency:.3e}s int8-channels={int8_frac:.0%}")
    print("higher lambda -> more channels on the fast int8 domain, the")
    print("TPU version of the paper's digital/AIMC trade (DESIGN.md §2)")


if __name__ == "__main__":
    main()
