"""LM training example with the full substrate: sharded synthetic data,
AdamW + warmup-cosine, async atomic checkpointing, restart, straggler
monitoring, optional int8 gradient compression.

Default is a CPU-sized model for a quick demo; scale up with the flags
(e.g. --steps 300 for the 'few hundred steps' run recorded in
EXPERIMENTS.md §Examples).

Run:  PYTHONPATH=src python examples/train_lm.py
      PYTHONPATH=src python examples/train_lm.py --resume   # restart path
"""
import argparse

from repro.launch import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    args = ap.parse_args()
    argv = ["--arch", args.arch, "--reduce", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--ckpt-dir", "/tmp/repro_ckpt",
            "--ckpt-every", "50"]
    if args.resume:
        argv.append("--resume")
    if args.compress_grads:
        argv.append("--compress-grads")
    losses = train.main(argv)
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
