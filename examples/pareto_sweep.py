"""Reproduce the paper's Fig. 4-style Pareto sweep (accuracy vs modeled
latency/energy on the DIANA cost models) on a synthetic CIFAR-10-geometry
task.  Writes experiments/paper/results_<preset>.json.

Run:  PYTHONPATH=src:. python examples/pareto_sweep.py --preset quick
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import paper_experiments


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="quick")
    args = ap.parse_args()
    results = paper_experiments.main(["--preset", args.preset])
    odimo = [r for r in results if r["kind"].startswith("odimo")]
    base = [r for r in results if r["kind"] == "baseline"]
    print(f"\nPareto points: {len(odimo)} ODiMO, {len(base)} baselines")
    print("Higher lambda => cheaper mapping (more AIMC channels):")
    for r in sorted(odimo, key=lambda r: r.get("lam", 0)):
        if r["kind"] == "odimo_diana":
            print(f"  lam={r['lam']:.0e} obj={r['objective']:>7s} "
                  f"acc={r['accuracy']:.3f} lat={r['latency']:.3e} "
                  f"en={r['energy']:.3e} A.Ch={r['aimc_ch']:.0%}")


if __name__ == "__main__":
    main()
